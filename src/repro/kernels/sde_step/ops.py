"""Shape-agnostic fused SDE-step ops: dispatch, custom VJPs, pytree API.

Three ops cover the solve hot loop (see ``sde_step.py`` for the kernels and
``ref.py`` for the numerics twins):

* :func:`tree_increment`        — ``k = f*h + g.dW`` (the driver-weighted
  increment; diagonal / general / no noise),
* :func:`tree_ws_stage`         — increment + Williamson 2N register update
  in one pass (subsumes ``kernels/williamson2n``, which takes ``k``
  precomputed),
* :func:`tree_axpy_chain`       — ``y + sum_i c_i k_i`` (Butcher stage
  preparation and output combination).

Every op is wrapped in a ``custom_vjp`` whose backward is closed-form (all
three are linear in their array operands) and itself fused: the Williamson
stage backward runs as a single Pallas pass, the rest as one fused XLA
elementwise expression.  This keeps the reversible adjoint's inner
``jax.vjp``-of-``step`` working through the kernels with no Pallas transpose
rule, under every adjoint.

Dispatch per leaf: the compiled Pallas path is used on TPU for states past the
tile size (general-noise variants additionally need lane-aligned ``(d, m)``);
``interpret=True`` — or the :func:`force_interpret` test/CI hook — runs the
same kernel bodies in Python on any backend; everywhere else the op *is* its
``ref.py`` twin, so CPU/GPU numerics are identical to the reference by
construction.
"""
from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp

from . import ref as _ref
from . import sde_step as _k

__all__ = [
    "force_interpret",
    "fused_increment",
    "fused_ws_stage",
    "fused_axpy_chain",
    "tree_increment",
    "tree_ws_stage",
    "tree_axpy_chain",
]

_TILE = _k.LANE * _k.SUBLANE

# Test/CI hook: force every op through the Pallas kernel bodies in interpret
# mode (Python on any backend) so kernel code paths are exercised end-to-end
# without a TPU.  Read at trace time.
_FORCE_INTERPRET = False


@contextlib.contextmanager
def force_interpret():
    """Run every fused op through its Pallas kernel in interpret mode."""
    global _FORCE_INTERPRET
    prev, _FORCE_INTERPRET = _FORCE_INTERPRET, True
    try:
        yield
    finally:
        _FORCE_INTERPRET = prev


def _mode(x: jax.Array, interpret: bool, aligned: bool = True) -> str:
    if interpret or _FORCE_INTERPRET:
        return "interpret"
    if jax.default_backend() == "tpu" and x.size >= _TILE and aligned:
        return "pallas"
    return "ref"


# -- 2D flattening ------------------------------------------------------------

def _to2d(x):
    flat = x.reshape(-1)
    pad = (-flat.size) % _TILE
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros(pad, x.dtype)])
    return flat.reshape(-1, _k.LANE)


def _from2d(x2, shape, n):
    return x2.reshape(-1)[:n].reshape(shape)


def _rows(x, trailing: int):
    """Flatten leading (batch) dims of ``x``, keeping ``trailing`` dims.

    Returns the (padded) 2D+ view plus ``(n, batch_shape)`` to undo it; rows
    are padded to a block multiple so the grid divides evenly.
    """
    batch = x.shape[:x.ndim - trailing]
    tail = x.shape[x.ndim - trailing:]
    n = 1
    for s in batch:
        n *= s
    flat = x.reshape((n,) + tail)
    block = n if n <= 8 else 128
    padded = -(-n // block) * block
    if padded != n:
        padding = jnp.zeros((padded - n,) + tail, x.dtype)
        flat = jnp.concatenate([flat, padding])
    return flat, n, batch, min(block, padded)


def _h_arr(h, dtype):
    return jnp.asarray(h, dtype).reshape(1, 1)


# -- driver-weighted increment ------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _increment(mode: str, noise: str, f, g, dW, h):
    if mode == "ref":
        if noise == "diagonal":
            return _ref.increment_diag_ref(f, g, dW, h)
        return _ref.increment_general_ref(f, g, dW, h)
    interp = mode == "interpret"
    if noise == "diagonal":
        f2 = _to2d(f)
        out = _k.increment_diag_2d(f2, _to2d(g), _to2d(dW),
                                   _h_arr(h, f.dtype), interpret=interp)
        return _from2d(out, f.shape, f.size)
    fr, n, batch, block = _rows(f, 1)
    gr = _rows(g, 2)[0]
    wr = _rows(dW, 1)[0]
    out = _k.increment_general_2d(fr, gr, wr, _h_arr(h, f.dtype),
                                  block_n=block, interpret=interp)
    return out[:n].reshape(batch + f.shape[f.ndim - 1:])


def _increment_fwd(mode, noise, f, g, dW, h):
    return _increment(mode, noise, f, g, dW, h), (f, g, dW, h)


def _increment_bwd(mode, noise, res, ct):
    f, g, dW, h = res
    ct_f = h * ct
    if noise == "diagonal":
        ct_g, ct_dW = dW * ct, g * ct
    else:
        ct_g = jnp.einsum("...d,...m->...dm", ct, dW)
        ct_dW = jnp.einsum("...dm,...d->...m", g, ct)
    ct_h = jnp.sum(f * ct).astype(h.dtype).reshape(jnp.shape(h))
    return ct_f, ct_g, ct_dW, ct_h


_increment.defvjp(_increment_fwd, _increment_bwd)


# -- prediffused increment (additive fast path: dW is already g.dW) ----------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _increment_pre(mode: str, f, w, h):
    if mode == "ref":
        return _ref.increment_pre_ref(f, w, h)
    out = _k.increment_pre_2d(_to2d(f), _to2d(w), _h_arr(h, f.dtype),
                              interpret=mode == "interpret")
    return _from2d(out, f.shape, f.size)


def _increment_pre_fwd(mode, f, w, h):
    return _increment_pre(mode, f, w, h), (f, h)


def _increment_pre_bwd(mode, res, ct):
    f, h = res
    ct_h = jnp.sum(f * ct).astype(h.dtype).reshape(jnp.shape(h))
    return h * ct, ct, ct_h


_increment_pre.defvjp(_increment_pre_fwd, _increment_pre_bwd)


def fused_increment(f, g, dW, h, *, noise: str, interpret: bool = False):
    """``k = f*h + g.dW`` for one leaf; fused on TPU, ref elsewhere.

    ``noise="prediffused"`` takes ``dW`` as the pre-weighted ``g.dW``
    increment (``g`` is ignored) — the additive fast path's cheaper variant.
    """
    if noise == "prediffused":
        return _increment_pre(_mode(f, interpret), f, dW,
                              jnp.asarray(h, f.dtype))
    if noise not in ("diagonal", "general"):
        raise ValueError(
            f"unknown noise mode {noise!r}; valid kernel modes: 'diagonal', "
            "'general', 'prediffused'"
        )
    aligned = noise == "diagonal" or (
        f.shape[-1] % _k.SUBLANE == 0 and dW.shape[-1] % _k.LANE == 0)
    mode = _mode(f, interpret, aligned)
    return _increment(mode, noise, f, g, dW, jnp.asarray(h, f.dtype))


# -- fused increment + Williamson 2N stage ------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _ws_stage(mode: str, noise: str, a: float, b: float, delta, y, f, g, dW, h):
    if mode == "ref":
        if noise == "diagonal":
            return _ref.ws_stage_diag_ref(delta, y, f, g, dW, h, a, b)
        return _ref.ws_stage_general_ref(delta, y, f, g, dW, h, a, b)
    interp = mode == "interpret"
    if noise == "diagonal":
        d2, y2 = _k.ws_stage_diag_2d(
            _to2d(delta), _to2d(y), _to2d(f), _to2d(g), _to2d(dW),
            _h_arr(h, f.dtype), a=a, b=b, interpret=interp)
        return _from2d(d2, delta.shape, delta.size), _from2d(y2, y.shape, y.size)
    dr, n, batch, block = _rows(delta, 1)
    d2, y2 = _k.ws_stage_general_2d(
        dr, _rows(y, 1)[0], _rows(f, 1)[0], _rows(g, 2)[0], _rows(dW, 1)[0],
        _h_arr(h, f.dtype), a=a, b=b, block_n=block, interpret=interp)
    shape = batch + delta.shape[delta.ndim - 1:]
    return d2[:n].reshape(shape), y2[:n].reshape(shape)


def _ws_stage_fwd(mode, noise, a, b, delta, y, f, g, dW, h):
    return _ws_stage(mode, noise, a, b, delta, y, f, g, dW, h), (f, g, dW, h)


def _ws_stage_bwd(mode, noise, a, b, res, ct):
    f, g, dW, h = res
    ct_d2, ct_y2 = ct
    if noise == "diagonal" and mode != "ref":
        ctd, ctf, ctg, ctdw = _k.ws_stage_diag_bwd_2d(
            _to2d(ct_d2), _to2d(ct_y2), _to2d(g), _to2d(dW),
            _h_arr(h, f.dtype), a=a, b=b, interpret=mode == "interpret")
        shp, n = f.shape, f.size
        ct_delta, ct_f = _from2d(ctd, shp, n), _from2d(ctf, shp, n)
        ct_g, ct_dW = _from2d(ctg, shp, n), _from2d(ctdw, shp, n)
    else:
        common = ct_d2 + b * ct_y2
        ct_delta, ct_f = a * common, h * common
        if noise == "diagonal":
            ct_g, ct_dW = dW * common, g * common
        else:
            ct_g = jnp.einsum("...d,...m->...dm", common, dW)
            ct_dW = jnp.einsum("...dm,...d->...m", g, common)
    ct_h = jnp.sum(f * (ct_d2 + b * ct_y2)).astype(h.dtype).reshape(jnp.shape(h))
    return ct_delta, ct_y2, ct_f, ct_g, ct_dW, ct_h


_ws_stage.defvjp(_ws_stage_fwd, _ws_stage_bwd)


# -- prediffused Williamson stage ---------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _ws_stage_pre(mode: str, a: float, b: float, delta, y, f, w, h):
    if mode == "ref":
        return _ref.ws_stage_pre_ref(delta, y, f, w, h, a, b)
    d2, y2 = _k.ws_stage_pre_2d(
        _to2d(delta), _to2d(y), _to2d(f), _to2d(w), _h_arr(h, f.dtype),
        a=a, b=b, interpret=mode == "interpret")
    return _from2d(d2, delta.shape, delta.size), _from2d(y2, y.shape, y.size)


def _ws_stage_pre_fwd(mode, a, b, delta, y, f, w, h):
    return _ws_stage_pre(mode, a, b, delta, y, f, w, h), (f, h)


def _ws_stage_pre_bwd(mode, a, b, res, ct):
    f, h = res
    ct_d2, ct_y2 = ct
    common = ct_d2 + b * ct_y2
    ct_h = jnp.sum(f * common).astype(h.dtype).reshape(jnp.shape(h))
    return a * common, ct_y2, h * common, common, ct_h


_ws_stage_pre.defvjp(_ws_stage_pre_fwd, _ws_stage_pre_bwd)


def fused_ws_stage(delta, y, f, g, dW, h, *, a: float, b: float, noise: str,
                   interpret: bool = False):
    """One fused Williamson stage for one leaf: returns ``(delta', y')``.

    ``noise="prediffused"``: ``dW`` is already the diffusion increment
    ``g.dW`` and ``g`` is ignored — one fewer operand stream per stage.
    """
    if noise == "prediffused":
        return _ws_stage_pre(_mode(f, interpret), float(a), float(b),
                             delta, y, f, dW, jnp.asarray(h, f.dtype))
    if noise not in ("diagonal", "general"):
        raise ValueError(
            f"unknown noise mode {noise!r}; valid kernel modes: 'diagonal', "
            "'general', 'prediffused'"
        )
    aligned = noise == "diagonal" or (
        f.shape[-1] % _k.SUBLANE == 0 and dW.shape[-1] % _k.LANE == 0)
    mode = _mode(f, interpret, aligned)
    return _ws_stage(mode, noise, float(a), float(b), delta, y, f, g, dW,
                     jnp.asarray(h, f.dtype))


# -- Butcher axpy chain -------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _axpy_chain(mode: str, coeffs, y, incs):
    if mode == "ref":
        return _ref.axpy_chain_ref(y, incs, coeffs)
    s = incs.shape[0]
    y2 = _to2d(y)
    incs2 = jnp.stack([_to2d(incs[i]) for i in range(s)])
    out = _k.axpy_chain_2d(y2, incs2, coeffs=coeffs,
                           interpret=mode == "interpret")
    return _from2d(out, y.shape, y.size)


def _axpy_chain_fwd(mode, coeffs, y, incs):
    return _axpy_chain(mode, coeffs, y, incs), None


def _axpy_chain_bwd(mode, coeffs, _, ct):
    c = jnp.asarray(coeffs, ct.dtype).reshape((-1,) + (1,) * ct.ndim)
    return ct, c * ct[None]


_axpy_chain.defvjp(_axpy_chain_fwd, _axpy_chain_bwd)


def fused_axpy_chain(y, incs, coeffs, *, interpret: bool = False):
    """``y + sum_i coeffs[i] * incs[i]`` for one leaf; ``incs`` is ``(s, ...)``."""
    return _axpy_chain(_mode(y, interpret), tuple(float(c) for c in coeffs),
                       y, incs)


# -- pytree layer (what core/solvers.py calls) --------------------------------

def tree_increment(f, g, dW, h, *, noise: str, interpret: bool = False):
    """Leafwise :func:`fused_increment` over matching state pytrees.

    ``noise="prediffused"`` maps over ``(f, dW)`` only (``g`` is None — the
    increment buffer is already diffusion-weighted).
    """
    if noise == "prediffused":
        return jax.tree_util.tree_map(
            lambda fi, wi: fused_increment(fi, None, wi, h, noise=noise,
                                           interpret=interpret),
            f, dW)
    return jax.tree_util.tree_map(
        lambda fi, gi, wi: fused_increment(fi, gi, wi, h, noise=noise,
                                           interpret=interpret),
        f, g, dW)


def tree_ws_stage(delta, y, f, g, dW, h, a: float, b: float, *, noise: str,
                  interpret: bool = False):
    """Leafwise fused Williamson stage; returns the ``(delta', y')`` pytrees.

    Unzips by explicit flatten/unflatten over ``delta``'s treedef — an
    ``is_leaf``-on-tuples trick would misfire on states that are themselves
    tuples (the product-group ``((N,), (N,))`` form).
    """
    d_leaves, treedef = jax.tree_util.tree_flatten(delta)
    leaves = lambda t: treedef.flatten_up_to(t)
    if noise == "prediffused":
        # g is a placeholder (dW is already g.dW): pair each leaf with None.
        g_leaves = [None] * len(d_leaves)
    else:
        g_leaves = leaves(g)
    pairs = [
        fused_ws_stage(di, yi, fi, gi, wi, h, a=a, b=b, noise=noise,
                       interpret=interpret)
        for di, yi, fi, gi, wi in zip(d_leaves, leaves(y), leaves(f),
                                      g_leaves, leaves(dW))
    ]
    delta2 = treedef.unflatten([p[0] for p in pairs])
    y2 = treedef.unflatten([p[1] for p in pairs])
    return delta2, y2


def tree_axpy_chain(y, incs, coeffs, *, interpret: bool = False):
    """Leafwise axpy chain over a list of increment pytrees.

    ``incs`` is a Python list of pytrees matching ``y``; each leaf set is
    stacked once and reduced in a single fused pass.
    """
    if not incs:
        return y
    stacked = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *incs)
    return jax.tree_util.tree_map(
        lambda yi, si: fused_axpy_chain(yi, si, coeffs, interpret=interpret),
        y, stacked)
