"""Fused SDE step kernels: driver-weighted increment + RK register updates.

See ``sde_step.py`` (Pallas kernels), ``ops.py`` (dispatch + custom VJPs +
pytree API — what ``core/solvers.py`` consumes behind ``use_kernels``), and
``ref.py`` (pure-jnp numerics twins).
"""
from . import ops, ref  # noqa: F401
from .ops import (  # noqa: F401
    force_interpret,
    fused_axpy_chain,
    fused_increment,
    fused_ws_stage,
    tree_axpy_chain,
    tree_increment,
    tree_ws_stage,
)
