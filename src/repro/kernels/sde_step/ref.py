"""Pure-jnp oracles for the fused SDE step kernels.

These are the numerics twins of the Pallas kernels in ``sde_step.py``: every
fused op must match its ``*_ref`` here to tolerance in interpret mode (tested
in the tier-1 lane), and the XLA fallback path in ``ops.py`` *is* these
functions, so non-TPU backends run exactly this arithmetic.
"""
from __future__ import annotations

import jax.numpy as jnp


def increment_diag_ref(f, g, dW, h):
    """k = f*h + g*dW (diagonal noise: elementwise product)."""
    return f * h + g * dW


def increment_general_ref(f, g, dW, h):
    """k = f*h + g@dW (general noise: ``(..., d, m) x (..., m) -> (..., d)``)."""
    return f * h + jnp.einsum("...dm,...m->...d", g, dW)


def increment_pre_ref(f, w, h):
    """k = f*h + w (prediffused additive noise: ``w`` is already ``g.dW``)."""
    return f * h + w


def ws_stage_diag_ref(delta, y, f, g, dW, h, a: float, b: float):
    """One fused Williamson 2N stage under diagonal noise.

    k = f*h + g*dW;  delta' = a*delta + k;  y' = y + b*delta'.
    """
    k = f * h + g * dW
    d2 = a * delta + k
    y2 = y + b * d2
    return d2, y2


def ws_stage_general_ref(delta, y, f, g, dW, h, a: float, b: float):
    """One fused Williamson 2N stage under general (einsum) noise."""
    k = f * h + jnp.einsum("...dm,...m->...d", g, dW)
    d2 = a * delta + k
    y2 = y + b * d2
    return d2, y2


def ws_stage_pre_ref(delta, y, f, w, h, a: float, b: float):
    """One fused Williamson 2N stage with a prediffused increment ``w = g.dW``:
    one fewer operand stream than the diagonal variant."""
    k = f * h + w
    d2 = a * delta + k
    y2 = y + b * d2
    return d2, y2


def axpy_chain_ref(y, incs, coeffs):
    """y + sum_i coeffs[i] * incs[i] over a stacked ``(s, ...)`` increment set.

    The Butcher stage-preparation / output-combination primitive: one weighted
    reduction instead of a chain of s separate axpys.
    """
    c = jnp.asarray(coeffs, incs.dtype).reshape((-1,) + (1,) * y.ndim)
    return y + jnp.sum(c * incs, axis=0)
