"""Dispatching wrapper for the SSD scan.

On TPU: the Pallas kernel.  Elsewhere: the vectorised chunked reference
(which is itself the form used by the LM substrate so the dry-run lowers a
realistic chunked computation, not a per-token scan).
"""
from __future__ import annotations

import jax

from .ref import ssd_chunked_ref, ssd_ref
from .ssd_scan import ssd_scan


def ssd(x, dt, A, B, C, *, chunk: int = 128, use_kernel: str = "auto"):
    """Returns y (b, l, h, dh).  See ref.ssd_ref for semantics."""
    if use_kernel == "interpret":
        return ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    if use_kernel == "auto" and jax.default_backend() == "tpu":
        return ssd_scan(x, dt, A, B, C, chunk=chunk)
    y, _ = ssd_chunked_ref(x, dt, A, B, C, chunk=min(chunk, x.shape[1]))
    return y
