"""Pure-jnp oracles for the Mamba2 SSD (state-space dual) scan.

Per head h with scalar decay ``A_h < 0``, state S in R^{dh x ds}::

    a_t = exp(A dt_t)
    S_t = a_t S_{t-1} + (dt_t x_t) (outer) B_t
    y_t = S_t C_t  (+ D x_t skip handled by the caller)

``ssd_ref`` is the sequential recurrence (ground truth); ``ssd_chunked_ref``
is the vectorised chunked form the Pallas kernel mirrors (and the form the LM
substrate uses on non-TPU backends).
"""
import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, B, C, S0=None):
    """x: (b, l, h, dh); dt: (b, l, h); A: (h,); B, C: (b, l, ds).

    Returns y: (b, l, h, dh) and final state (b, h, dh, ds).
    """
    b, l, h, dh = x.shape
    ds = B.shape[-1]
    if S0 is None:
        S0 = jnp.zeros((b, h, dh, ds), jnp.promote_types(x.dtype, jnp.float32))

    def step(S, inp):
        xt, dtt, Bt, Ct = inp  # (b,h,dh), (b,h), (b,ds), (b,ds)
        a = jnp.exp(A[None, :] * dtt)  # (b,h)
        dx = dtt[..., None] * xt  # (b,h,dh)
        S = a[..., None, None] * S + dx[..., None] * Bt[:, None, None, :]
        y = jnp.einsum("bhds,bs->bhd", S, Ct)
        return S, y

    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(B, 1, 0),
        jnp.moveaxis(C, 1, 0),
    )
    S, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1), S


def ssd_chunked_ref(x, dt, A, B, C, chunk: int = 64, S0=None):
    """Chunked SSD: intra-chunk attention-like term + inter-chunk state pass."""
    b, l, h, dh = x.shape
    ds = B.shape[-1]
    assert l % chunk == 0
    nc = l // chunk
    if S0 is None:
        S0 = jnp.zeros((b, h, dh, ds), jnp.promote_types(x.dtype, jnp.float32))

    xr = x.reshape(b, nc, chunk, h, dh)
    dtr = dt.reshape(b, nc, chunk, h)
    Br = B.reshape(b, nc, chunk, ds)
    Cr = C.reshape(b, nc, chunk, ds)

    lam = A[None, None, None, :] * dtr  # (b,nc,L,h) log-decay increments
    cum = jnp.cumsum(lam, axis=2)  # inclusive cumsum
    # intra-chunk: M[t, s] = (C_t . B_s) * exp(cum_t - cum_s) for s <= t
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,nc,t,s,h)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    seg = jnp.where(mask[None, None, :, :, None], seg, -jnp.inf)
    CB = jnp.einsum("bnts,bnus->bntu", Cr, Br)  # (b,nc,t,s)
    M = CB[..., None] * jnp.exp(seg)  # (b,nc,t,s,h)
    dx = dtr[..., None] * xr  # (b,nc,L,h,dh)
    y_intra = jnp.einsum("bntsh,bnshd->bnthd", M, dx)

    # inter-chunk: states at chunk boundaries.
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (b,nc,h)
    # contribution of chunk n to its end-state:
    inc = jnp.einsum(
        "bnsh,bnshd,bnsk->bnhdk", jnp.exp(cum[:, :, -1:, :] - cum), dx, Br
    )  # (b,nc,h,dh,ds)

    def pass_state(S, inp):
        decay, incn = inp
        S_out = S  # state entering the chunk
        S = decay[..., None, None] * S + incn
        return S, S_out

    decays = jnp.moveaxis(chunk_decay, 1, 0)
    incs = jnp.moveaxis(inc, 1, 0)
    S_final, S_ins = jax.lax.scan(pass_state, S0, (decays, incs))
    S_ins = jnp.moveaxis(S_ins, 0, 1)  # (b,nc,h,dh,ds) state entering each chunk

    y_inter = jnp.einsum(
        "bnth,bnhdk,bntk->bnthd", jnp.exp(cum), S_ins, Cr
    )
    y = (y_intra + y_inter).reshape(b, l, h, dh)
    return y, S_final
