"""Chunked Mamba2 SSD scan (TPU Pallas).

Grid: ``(batch, heads, n_chunks)`` with the chunk axis innermost and
*sequential* — the running state S (dh, ds) lives in VMEM scratch across chunk
iterations, so the recurrence never round-trips through HBM.  Each chunk does
three MXU contractions (CB^T, M @ dx, state outer-products) on
(chunk x chunk) and (chunk x dh/ds) tiles: with chunk = ds = 128 and dh = 64,
everything is MXU-shaped.

Layouts (contiguous in the model): x (b, l, h, dh), dt (b, l, h), A (h,),
B/C (b, l, ds) single SSM group, y (b, l, h, dh).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(chunk, x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_ref):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (L, dh)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (L,)
    A = a_ref[0].astype(jnp.float32)  # scalar
    B = b_ref[0].astype(jnp.float32)  # (L, ds)
    C = c_ref[0].astype(jnp.float32)  # (L, ds)

    lam = A * dt  # (L,) log-decay, <= 0
    cum = jnp.cumsum(lam)  # (L,)
    seg = cum[:, None] - cum[None, :]  # (t, s)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(rows >= cols, jnp.exp(seg), 0.0)
    CB = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (t, s)
    M = CB * decay
    dx = dt[:, None] * x  # (L, dh)
    y_intra = jax.lax.dot_general(
        M, dx, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, dh)

    S_in = s_ref[...]  # (dh, ds) state entering the chunk
    y_inter = jnp.exp(cum)[:, None] * jax.lax.dot_general(
        C, S_in, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, ds) . (dh, ds)^T -> (L, dh)

    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: S_out = exp(cum_L) S_in + sum_s exp(cum_L - cum_s) dx_s B_s^T
    w = jnp.exp(cum[-1] - cum)  # (L,)
    s_ref[...] = jnp.exp(cum[-1]) * S_in + jax.lax.dot_general(
        (w[:, None] * dx), B, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (dh, ds)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,  # (b, l, h, dh)
    dt: jax.Array,  # (b, l, h)
    A: jax.Array,  # (h,)
    B: jax.Array,  # (b, l, ds)
    C: jax.Array,  # (b, l, ds)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, l, h, dh = x.shape
    ds = B.shape[-1]
    chunk = min(chunk, l)
    assert l % chunk == 0
    nc = l // chunk
    grid = (b, h, nc)
    kernel = functools.partial(_kernel, chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, dh), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, 1), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
            pl.BlockSpec((1, chunk, ds), lambda ib, ih, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, ds), lambda ib, ih, ic: (ib, ic, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, dh), lambda ib, ih, ic: (ib, ic, ih, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((dh, ds), jnp.float32)],
        # jax >= 0.5 renamed TPUCompilerParams -> CompilerParams; accept both.
        compiler_params=getattr(pltpu, "CompilerParams",
                                getattr(pltpu, "TPUCompilerParams", None))(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, dt, A, B, C)
