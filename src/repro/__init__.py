"""repro: EES schemes for Neural SDEs on Lie groups — production JAX framework.

Layers: core (paper), nsde (paper benchmarks), models (assigned LM archs),
kernels (Pallas TPU), data/optim/train/serving (substrate), configs, launch.
"""
__version__ = "1.0.0"
