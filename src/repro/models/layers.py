"""Transformer building blocks: norms, RoPE, GQA attention block, MLPs.

All parameters are plain pytrees (dicts of arrays); every block is a pure
function ``f(params, x, ...)``.  Weight layouts are chosen so the natural
tensor-parallel sharding is the second axis of up-projections and the first
axis of down-projections ("megatron" style).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .attention import decode_attention, gqa_attention
from .common import ModelOptions

__all__ = [
    "rmsnorm",
    "nonparam_layernorm",
    "apply_norm",
    "rope",
    "init_attn_block",
    "attn_block",
    "attn_block_decode",
    "init_mlp",
    "mlp_block",
]


# ---------------------------------------------------------------------------
# Norms (computed in f32, cast back).
# ---------------------------------------------------------------------------

def rmsnorm(scale, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def nonparam_layernorm(x, eps: float = 1e-5):
    """OLMo-style non-parametric LayerNorm: no scale, no bias."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def apply_norm(kind: str, scale, x):
    if kind == "rmsnorm":
        return rmsnorm(scale, x)
    if kind == "nonparam_ln":
        return nonparam_layernorm(x)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# RoPE.
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float = 10000.0):
    """x: (..., S, hd) with hd even; positions: (S,) or (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention block.
# ---------------------------------------------------------------------------

def init_attn_block(cfg, key, dtype):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 5)
    sd = 1.0 / math.sqrt(d)
    p = {
        "wq": (jax.random.normal(ks[0], (d, cfg.n_heads * hd)) * sd).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, cfg.n_kv_heads * hd)) * sd).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, cfg.n_kv_heads * hd)) * sd).astype(dtype),
        "wo": (jax.random.normal(ks[3], (cfg.n_heads * hd, d)) * sd).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    if cfg.norm == "rmsnorm":
        p["ln"] = jnp.ones((d,), dtype)
    return p


def _qkv(cfg, p, x, positions):
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_block(cfg, p, x, opts: ModelOptions):
    """Pre-norm attention sub-block (residual added by caller)."""
    b, s, d = x.shape
    h = apply_norm(cfg.norm, p.get("ln"), x)
    positions = jnp.arange(s)
    q, k, v = _qkv(cfg, p, h, positions)
    q = opts.shard.heads(q)
    k = opts.shard.heads(k)
    v = opts.shard.heads(v)
    if opts.attn_impl == "stub":
        o = q  # dry-run cost isolation: no mixing compute / score traffic
    else:
        o = gqa_attention(
            q, k, v, causal=cfg.causal, use_flash=opts.use_flash, chunk=opts.attn_chunk
        )
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.resolved_head_dim)
    return o @ p["wo"]


def attn_block_decode(cfg, p, x, k_cache, v_cache, pos):
    """One-token attention against a cache; returns (out, k_cache, v_cache)."""
    b, one, d = x.shape
    hd = cfg.resolved_head_dim
    h = apply_norm(cfg.norm, p.get("ln"), x)
    q, k, v = _qkv(cfg, p, h, pos[None] if jnp.ndim(pos) == 0 else pos)
    # q, k, v: (B, H/KV, 1, hd); insert k, v at position pos.
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), pos, axis=2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), pos, axis=2)
    o = decode_attention(q, k_cache, v_cache, pos)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, cfg.n_heads * hd)
    return o @ p["wo"], k_cache, v_cache


# ---------------------------------------------------------------------------
# MLPs.
# ---------------------------------------------------------------------------

def init_mlp(cfg, key, dtype, d_ff: Optional[int] = None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    sd_in, sd_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    if cfg.mlp == "swiglu":
        p = {
            "wg": (jax.random.normal(ks[0], (d, f)) * sd_in).astype(dtype),
            "wu": (jax.random.normal(ks[1], (d, f)) * sd_in).astype(dtype),
            "wd": (jax.random.normal(ks[2], (f, d)) * sd_out).astype(dtype),
        }
    else:  # gelu
        p = {
            "wu": (jax.random.normal(ks[1], (d, f)) * sd_in).astype(dtype),
            "wd": (jax.random.normal(ks[2], (f, d)) * sd_out).astype(dtype),
        }
    if cfg.norm == "rmsnorm":
        p["ln"] = jnp.ones((d,), dtype)
    return p


def mlp_block(cfg, p, x, opts: ModelOptions):
    h = apply_norm(cfg.norm, p.get("ln"), x)
    if cfg.mlp == "swiglu":
        inner = jax.nn.silu(h @ p["wg"]) * (h @ p["wu"])
    else:
        inner = jax.nn.gelu(h @ p["wu"])
    inner = opts.shard.ffn(inner)
    return inner @ p["wd"]
