"""Config-driven model assembly: init, forward, loss, train/serve steps.

One code path serves all 10 assigned architectures:

* dense / vlm / audio — stacked (attention + MLP) blocks, scanned over layers
  with stacked parameters (compact HLO, MaxText-style);
* moe — attention + capacity-based top-k MoE blocks;
* ssm — stacked Mamba2 (SSD) blocks;
* hybrid (zamba2) — scanned Mamba2 stack with a *shared* attention+MLP block
  applied every ``shared_attn_every`` layers via ``lax.cond`` (the shared
  parameters are scan-invariant, so they appear once in the HLO and once in
  memory — the parameter-sharing that makes zamba2 7B-sized).

Training uses next-token CE with a validity mask; decode carries KV caches
(attention) and conv/SSD states (mamba) — state is O(1) in context for SSM,
O(S) for attention, which is what the long_500k cell probes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

from .common import ModelOptions, dtype_of
from .layers import (
    apply_norm,
    attn_block,
    attn_block_decode,
    init_attn_block,
    init_mlp,
    mlp_block,
)
from .moe import init_moe, moe_block
from .ssm import init_mamba_block, init_mamba_cache, mamba_block, mamba_block_decode

__all__ = [
    "init_params",
    "forward",
    "loss_fn",
    "init_cache",
    "serve_step",
    "make_train_step",
    "make_serve_step",
]


# ---------------------------------------------------------------------------
# Parameter init (used directly for smoke tests; via eval_shape for dry-run).
# ---------------------------------------------------------------------------

def _init_layer(cfg: ArchConfig, key, dtype) -> Dict[str, Any]:
    if cfg.family in ("ssm", "hybrid"):
        return {"mamba": init_mamba_block(cfg, key, dtype)}
    k1, k2 = jax.random.split(key)
    layer = {"attn": init_attn_block(cfg, k1, dtype)}
    if cfg.family == "moe":
        layer["moe"] = init_moe(cfg, k2, dtype)
    else:
        layer["mlp"] = init_mlp(cfg, k2, dtype)
    return layer


def init_params(cfg: ArchConfig, key) -> Dict[str, Any]:
    dtype = dtype_of(cfg.dtype)
    keys = jax.random.split(key, 8)
    p: Dict[str, Any] = {}
    p["embed"] = (
        jax.random.normal(keys[0], (cfg.padded_vocab, cfg.d_model)) * 0.02
    ).astype(dtype)
    # stacked per-layer params for lax.scan
    layer_keys = jax.random.split(keys[1], cfg.n_layers)
    p["layers"] = jax.vmap(lambda k: _init_layer(cfg, k, dtype))(layer_keys)
    if cfg.family == "hybrid" and cfg.shared_attn_every:
        p["shared"] = {
            "attn": init_attn_block(cfg, keys[2], dtype),
            "mlp": init_mlp(cfg, keys[3], dtype),
        }
    if cfg.norm == "rmsnorm":
        p["final_norm"] = jnp.ones((cfg.d_model,), dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = (
            jax.random.normal(keys[4], (cfg.d_model, cfg.padded_vocab)) * 0.02
        ).astype(dtype)
    if cfg.frontend == "patch":
        p["vision_proj"] = (
            jax.random.normal(keys[5], (cfg.frontend_dim, cfg.d_model)) * 0.02
        ).astype(dtype)
    if cfg.frontend == "frames":
        p["frame_proj"] = (
            jax.random.normal(keys[6], (cfg.frontend_dim, cfg.d_model)) * 0.02
        ).astype(dtype)
    return p


# ---------------------------------------------------------------------------
# Forward (full-sequence: training and prefill).
# ---------------------------------------------------------------------------

def _embed_inputs(cfg: ArchConfig, params, batch) -> jax.Array:
    if cfg.frontend == "frames":
        return batch["frames"] @ params["frame_proj"]
    h = jnp.take(params["embed"], batch["tokens"], axis=0)
    if cfg.frontend == "patch" and "vision_embeds" in batch:
        vis = batch["vision_embeds"] @ params["vision_proj"]
        h = jax.lax.dynamic_update_slice(h, vis.astype(h.dtype), (0, 0, 0))
    return h


def _mask_pad_vocab(cfg, logits):
    if cfg.padded_vocab == cfg.vocab:
        return logits
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(col < cfg.vocab, logits, jnp.asarray(-1e9, logits.dtype))


def _layer_apply(cfg, opts, shared, h, layer_params, idx):
    """One scanned layer; returns (h, aux_loss_increment)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family in ("ssm", "hybrid"):
        if cfg.family == "hybrid" and cfg.shared_attn_every and shared is not None:
            def with_shared(hh):
                hh = hh + attn_block(cfg, shared["attn"], hh, opts)
                hh = hh + mlp_block(cfg, shared["mlp"], hh, opts)
                return hh

            h = jax.lax.cond(
                idx % cfg.shared_attn_every == 0, with_shared, lambda hh: hh, h
            )
        h = h + mamba_block(cfg, layer_params["mamba"], h, opts)
        return h, aux
    h = h + attn_block(cfg, layer_params["attn"], h, opts)
    if cfg.family == "moe":
        out, aux = moe_block(cfg, layer_params["moe"], h, opts)
        h = h + out
    else:
        h = h + mlp_block(cfg, layer_params["mlp"], h, opts)
    return h, aux


def forward(
    cfg: ArchConfig,
    params,
    batch,
    opts: ModelOptions = ModelOptions(),
    head_positions: str = "all",
) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits (B, S, V) — or (B, 1, V) for head_positions='last',
    the prefill case — and the MoE aux-loss scalar)."""
    h = _embed_inputs(cfg, params, batch)
    h = opts.shard.hidden(h)
    shared = params.get("shared")

    def body(carry, xs):
        h, aux = carry
        layer_params, idx = xs
        h, aux_inc = _layer_apply(cfg, opts, shared, h, layer_params, idx)
        h = opts.shard.hidden(h)
        if opts.bf16_ar_barrier:
            h = jax.lax.optimization_barrier(h)
        return (h, aux + aux_inc), None

    if opts.remat:
        body = jax.checkpoint(body)

    (h, aux), _ = jax.lax.scan(
        body,
        (h, jnp.zeros((), jnp.float32)),
        (params["layers"], jnp.arange(cfg.n_layers)),
    )
    if cfg.norm == "rmsnorm":
        h = apply_norm(cfg.norm, params["final_norm"], h)
    else:
        h = apply_norm(cfg.norm, None, h)
    if head_positions == "last":
        h = h[:, -1:, :]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ head
    logits = _mask_pad_vocab(cfg, logits)
    if opts.logits_f32:
        logits = logits.astype(jnp.float32)
    return logits, aux


def loss_fn(cfg: ArchConfig, params, batch, opts: ModelOptions = ModelOptions()):
    """Masked next-token cross-entropy (+0.01 * MoE aux)."""
    logits, aux = forward(cfg, params, batch, opts)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    if cfg.family == "moe":
        loss = loss + 0.01 * aux / cfg.n_layers
    return loss


# ---------------------------------------------------------------------------
# Decode (serve_step): one new token against a cache of length seq_len.
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_seq: int) -> Dict[str, Any]:
    dtype = dtype_of(cfg.dtype)
    hd = cfg.resolved_head_dim
    cache: Dict[str, Any] = {}
    if cfg.family in ("ssm", "hybrid"):
        cache["mamba"] = jax.vmap(
            lambda _: init_mamba_cache(cfg, batch, dtype)
        )(jnp.arange(cfg.n_layers))
        if cfg.family == "hybrid" and cfg.shared_attn_every:
            n_inv = (cfg.n_layers + cfg.shared_attn_every - 1) // cfg.shared_attn_every
            cache["shared_k"] = jnp.zeros(
                (n_inv, batch, cfg.n_kv_heads, max_seq, hd), dtype
            )
            cache["shared_v"] = jnp.zeros_like(cache["shared_k"])
    else:
        cache["k"] = jnp.zeros(
            (cfg.n_layers, batch, cfg.n_kv_heads, max_seq, hd), dtype
        )
        cache["v"] = jnp.zeros_like(cache["k"])
    return cache


def serve_step(
    cfg: ArchConfig,
    params,
    cache,
    tokens: jax.Array,  # (B,) current token ids
    pos: jax.Array,  # scalar int32: index where this token sits
    opts: ModelOptions = ModelOptions(),
):
    """Decode one token; returns (logits (B, V), new_cache)."""
    h = jnp.take(params["embed"], tokens[:, None], axis=0)  # (B, 1, D)
    h = opts.shard.hidden(h)
    shared = params.get("shared")

    if cfg.family in ("ssm", "hybrid"):
        sk = cache.get("shared_k")
        sv = cache.get("shared_v")

        def body(carry, xs):
            h, sk, sv = carry
            layer_params, lcache, idx = xs
            if cfg.family == "hybrid" and cfg.shared_attn_every:
                inv = idx // cfg.shared_attn_every

                def with_shared(args):
                    hh, sk, sv = args
                    kc = jax.lax.dynamic_index_in_dim(sk, inv, 0, keepdims=False)
                    vc = jax.lax.dynamic_index_in_dim(sv, inv, 0, keepdims=False)
                    o, kc, vc = attn_block_decode(cfg, shared["attn"], hh, kc, vc, pos)
                    kc = opts.constrain_cache("k", kc)
                    vc = opts.constrain_cache("v", vc)
                    hh = hh + o
                    hh = hh + mlp_block(cfg, shared["mlp"], hh, ModelOptions())
                    sk = jax.lax.dynamic_update_index_in_dim(sk, kc, inv, 0)
                    sv = jax.lax.dynamic_update_index_in_dim(sv, vc, inv, 0)
                    return hh, sk, sv

                h, sk, sv = jax.lax.cond(
                    idx % cfg.shared_attn_every == 0,
                    with_shared,
                    lambda args: args,
                    (h, sk, sv),
                )
            out, new_lcache = mamba_block_decode(cfg, layer_params["mamba"], h, lcache)
            new_lcache = {k: opts.constrain_cache(k, v) for k, v in new_lcache.items()}
            return (h + out, sk, sv), new_lcache

        (h, sk, sv), new_mamba = jax.lax.scan(
            body, (h, sk, sv), (params["layers"], cache["mamba"], jnp.arange(cfg.n_layers))
        )
        new_cache = dict(cache, mamba=new_mamba)
        if sk is not None:
            new_cache["shared_k"] = sk
            new_cache["shared_v"] = sv
    else:

        def body(h, xs):
            layer_params, kc, vc = xs
            o, kc, vc = attn_block_decode(cfg, layer_params["attn"], h, kc, vc, pos)
            kc = opts.constrain_cache("k", kc)
            vc = opts.constrain_cache("v", vc)
            h = h + o
            h = opts.shard.hidden(h)
            if cfg.family == "moe":
                out, _ = moe_block(cfg, layer_params["moe"], h, opts)
                h = h + out
            else:
                h = h + mlp_block(cfg, layer_params["mlp"], h, opts)
            return h, (kc, vc)

        h, (nk, nv) = jax.lax.scan(body, h, (params["layers"], cache["k"], cache["v"]))
        new_cache = dict(cache, k=nk, v=nv)

    if cfg.norm == "rmsnorm":
        h = apply_norm(cfg.norm, params["final_norm"], h)
    else:
        h = apply_norm(cfg.norm, None, h)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = _mask_pad_vocab(cfg, h @ head)[:, 0].astype(jnp.float32)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Step builders.
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, optimizer, opts: ModelOptions = ModelOptions()):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, batch, opts))(params)
        params, opt_state, gnorm = optimizer.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_serve_step(cfg: ArchConfig, opts: ModelOptions = ModelOptions()):
    def step(params, cache, tokens, pos):
        return serve_step(cfg, params, cache, tokens, pos, opts)

    return step
