"""Attention paths for the LM substrate.

Three interchangeable implementations of causal/bidirectional GQA attention:

* ``flash`` — the Pallas TPU kernel (repro.kernels.flash_attention);
* ``chunked`` — jnp online-softmax over query chunks: O(S * chunk) live
  memory instead of O(S^2); what the dry-run lowers (CPU host cannot lower
  Pallas) and numerically identical to flash;
* ``naive`` — materialised scores; only sensible for tiny smoke shapes.

All paths accept q (B, H, Sq, hd), k/v (B, KV, Sk, hd) and broadcast KV heads
by GQA grouping.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import attention as flash_dispatch

__all__ = ["gqa_attention", "decode_attention"]


def _chunked(q, k, v, *, causal: bool, sm_scale: float, chunk: int):
    b, hq, sq, d = q.shape
    _, hk, sk, _ = k.shape
    group = hq // hk
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    chunk = min(chunk, sq)
    if sq % chunk:
        chunk = math.gcd(sq, chunk) or sq
    nq = sq // chunk

    qs = q.reshape(b, hq, nq, chunk, d)

    def one(idx, qc):
        s = jnp.einsum(
            "bhqd,bhkd->bhqk", qc.astype(jnp.float32), kr.astype(jnp.float32)
        ) * sm_scale
        if causal:
            rows = idx * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk, sk), 0)
            cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, sk), 1)
            s = jnp.where(rows[None, None] >= cols[None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))

    out = jax.lax.map(lambda args: one(*args), (jnp.arange(nq), jnp.moveaxis(qs, 2, 0)))
    out = jnp.moveaxis(out, 0, 2).reshape(b, hq, sq, d)
    return out.astype(q.dtype)


def gqa_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    sm_scale: Optional[float] = None,
    use_flash: str = "auto",
    chunk: int = 512,
):
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(q.shape[-1])
    if use_flash in ("auto", "interpret"):
        if use_flash == "interpret" or jax.default_backend() == "tpu":
            return flash_dispatch(
                q, k, v, causal=causal, sm_scale=sm_scale, use_kernel=use_flash
            )
    return _chunked(q, k, v, causal=causal, sm_scale=sm_scale, chunk=chunk)


def decode_attention(q, k_cache, v_cache, pos, *, sm_scale: Optional[float] = None):
    """Single-token attention against a (B, KV, S_max, hd) cache.

    ``pos`` is the index of the *current* token (attend to cols <= pos).
    O(S_max) per token — the sub-quadratic decode path.
    """
    b, hq, one, d = q.shape
    _, hk, smax, _ = k_cache.shape
    group = hq // hk
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    kr = jnp.repeat(k_cache, group, axis=1)
    vr = jnp.repeat(v_cache, group, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32)
    ) * sm_scale  # (B, H, 1, S)
    cols = jax.lax.broadcasted_iota(jnp.int32, (smax,), 0)
    s = jnp.where(cols[None, None, None, :] <= pos, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)
