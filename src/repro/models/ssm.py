"""Mamba2 (SSD) block: chunked-scan training path + O(1)-state decode path.

Structure follows the Mamba2 reference — in_proj -> (z | x | B | C | dt),
causal depthwise conv over (x | B | C), SSD scan with per-head scalar decay,
gated RMSNorm, out_proj — with one TPU adaptation (DESIGN.md §Hardware
adaptation): the packed ``in_proj`` of the CUDA implementation is split into
separate per-stream projections.  The packed layout exists to feed one fused
GPU kernel; under XLA the separate matmuls fuse anyway, and the split gives
each stream a clean tensor-parallel sharding (d_inner and d_state shard on
the "model" axis independently; tiny per-head vectors replicate).

Single SSM group (B/C shared across heads).  The training path calls
:func:`repro.kernels.ssd_scan.ops.ssd` (Pallas kernel on TPU, chunked jnp
elsewhere).  Decode carries (conv_state, ssm_state): state size is
independent of context length — this is what makes the ``long_500k`` cell
runnable for the SSM/hybrid archs.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ops import ssd

from .common import ModelOptions
from .layers import rmsnorm

__all__ = ["init_mamba_block", "mamba_block", "mamba_block_decode", "init_mamba_cache"]


def _dims(cfg):
    di = cfg.d_inner
    ds = cfg.ssm_state
    nh = cfg.ssm_heads
    return di, ds, nh


def init_mamba_block(cfg, key, dtype):
    d = cfg.d_model
    di, ds, nh = _dims(cfg)
    ks = jax.random.split(key, 9)
    sd = 1.0 / math.sqrt(d)
    K = cfg.ssm_conv
    return {
        "ln": jnp.ones((d,), dtype),
        "wz": (jax.random.normal(ks[0], (d, di)) * sd).astype(dtype),
        "wx": (jax.random.normal(ks[1], (d, di)) * sd).astype(dtype),
        "wB": (jax.random.normal(ks[2], (d, ds)) * sd).astype(dtype),
        "wC": (jax.random.normal(ks[3], (d, ds)) * sd).astype(dtype),
        "wdt": (jax.random.normal(ks[4], (d, nh)) * sd).astype(dtype),
        "conv_x": (jax.random.normal(ks[5], (K, di)) * 0.1).astype(dtype),
        "conv_B": (jax.random.normal(ks[6], (K, ds)) * 0.1).astype(dtype),
        "conv_C": (jax.random.normal(ks[7], (K, ds)) * 0.1).astype(dtype),
        "conv_bx": jnp.zeros((di,), dtype),
        "conv_bB": jnp.zeros((ds,), dtype),
        "conv_bC": jnp.zeros((ds,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "gate_norm": jnp.ones((di,), dtype),
        "out_proj": (jax.random.normal(ks[8], (di, d)) / math.sqrt(di)).astype(dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv along seq: x (B, S, C), w (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + x.shape[1], :] * w[i] for i in range(k))
    return out + b


def mamba_block(cfg, p, xin, opts: ModelOptions):
    """Training / prefill path: full sequence via chunked SSD."""
    bsz, s, d = xin.shape
    di, ds, nh = _dims(cfg)
    h = rmsnorm(p["ln"], xin)
    z = h @ p["wz"]
    x = h @ p["wx"]
    B = h @ p["wB"]
    C = h @ p["wC"]
    dt = h @ p["wdt"]
    x = jax.nn.silu(_causal_conv(x, p["conv_x"], p["conv_bx"]))
    B = jax.nn.silu(_causal_conv(B, p["conv_B"], p["conv_bB"]))
    C = jax.nn.silu(_causal_conv(C, p["conv_C"], p["conv_bC"]))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(p["A_log"])  # (nh,)
    xh = x.reshape(bsz, s, nh, cfg.ssm_head_dim)
    if opts.attn_impl == "stub":
        y = xh.astype(jnp.float32) * dt[..., None]  # cost isolation (dry-run)
    else:
        y = ssd(
            xh.astype(jnp.float32),
            dt,
            A,
            B.astype(jnp.float32),
            C.astype(jnp.float32),
            chunk=opts.ssd_chunk,
            use_kernel=opts.use_flash,  # same dispatch policy as attention
        )
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, di).astype(xin.dtype)
    y = rmsnorm(p["gate_norm"], y * jax.nn.silu(z))
    return y @ p["out_proj"]


def init_mamba_cache(cfg, batch, dtype):
    di, ds, nh = _dims(cfg)
    K = cfg.ssm_conv
    return {
        "conv_x": jnp.zeros((batch, K - 1, di), dtype),
        "conv_B": jnp.zeros((batch, K - 1, ds), dtype),
        "conv_C": jnp.zeros((batch, K - 1, ds), dtype),
        "state": jnp.zeros((batch, nh, cfg.ssm_head_dim, ds), jnp.float32),
    }


def _conv_step(cache, new, w, b):
    window = jnp.concatenate([cache, new[:, None]], axis=1)  # (B, K, C)
    out = jnp.einsum("bkc,kc->bc", window, w) + b
    return out, window[:, 1:]


def mamba_block_decode(cfg, p, xin, cache):
    """One-token step: O(1) state update (the sub-quadratic decode path)."""
    bsz, one, d = xin.shape
    di, ds, nh = _dims(cfg)
    h = rmsnorm(p["ln"], xin)[:, 0]  # (B, d)
    z = h @ p["wz"]
    x = h @ p["wx"]
    B = h @ p["wB"]
    C = h @ p["wC"]
    dt = h @ p["wdt"]
    x, conv_x = _conv_step(cache["conv_x"], x, p["conv_x"], p["conv_bx"])
    B, conv_B = _conv_step(cache["conv_B"], B, p["conv_B"], p["conv_bB"])
    C, conv_C = _conv_step(cache["conv_C"], C, p["conv_C"], p["conv_bC"])
    x, B, C = jax.nn.silu(x), jax.nn.silu(B), jax.nn.silu(C)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, nh)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(A[None] * dt)  # (B, nh)
    xh = x.reshape(bsz, nh, cfg.ssm_head_dim).astype(jnp.float32)
    dx = dt[..., None] * xh  # (B, nh, dh)
    S = a[..., None, None] * cache["state"] + dx[..., None] * B.astype(jnp.float32)[
        :, None, None, :
    ]
    y = jnp.einsum("bhds,bs->bhd", S, C.astype(jnp.float32))
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(bsz, di).astype(xin.dtype)
    y = rmsnorm(p["gate_norm"], y * jax.nn.silu(z))
    out = (y @ p["out_proj"])[:, None]
    return out, {"conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C, "state": S}
