"""EES residual-stream integration: the paper's technique applied to LM depth.

A pre-norm transformer layer is ``y_out = y + F(y)`` with
``F(y) = attn(y) + mlp(y + attn(y))`` — an Euler step of the depth-ODE
``dy/dt = F_l(y)`` with step 1.  Replacing Euler with one EES(2,5) 2N step per
layer gives a *near-reversible* depth integration: the backward pass
reconstructs layer inputs from layer outputs (``Phi_{-h}``, accurate to
O(h^6)) instead of storing them, so training activation memory is **O(1) in
depth** — the paper's reversible adjoint with depth playing the role of time.

This is a beyond-paper integration (it changes the function computed: 3 stage
evaluations per layer, continuous-depth semantics).  It is opt-in and never
used for the baseline roofline cells; see DESIGN.md §Arch-applicability.

At ``depth_step -> 0`` behaviour approaches the identity; ``depth_step = 1``
with a single Euler tableau would recover the vanilla layer exactly (tested).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.williamson import EES25_2N

__all__ = ["ees_depth_solve", "euler_depth_solve"]


def _ees_step(block_fn, lp, y, step: float):
    """One EES(2,5) 2N step of dy/dt = block_fn(lp, y)."""
    delta = jnp.zeros_like(y)
    for l in range(EES25_2N.stages):
        k = step * block_fn(lp, y)
        delta = EES25_2N.A[l] * delta + k
        y = y + EES25_2N.B[l] * delta
    return y


def euler_depth_solve(block_fn, layers, y0, step: float = 1.0):
    """Vanilla residual stack (Euler): y <- y + step * F_l(y).  Reference."""

    def body(y, lp):
        return y + step * block_fn(lp, y), None

    y, _ = jax.lax.scan(body, y0, layers)
    return y


def ees_depth_solve(
    block_fn: Callable,
    layers,  # stacked per-layer params, leading axis L
    y0,
    step: float = 1.0,
    adjoint: str = "reversible",
):
    """Integrate the depth-ODE with EES(2,5); reversible O(1)-memory backward.

    ``block_fn(layer_params, y) -> F(y)`` must be side-effect free.
    """
    if adjoint == "full":
        def body(y, lp):
            return _ees_step(block_fn, lp, y, step), None

        y, _ = jax.lax.scan(body, y0, layers)
        return y

    if adjoint != "reversible":
        raise ValueError(adjoint)

    def _forward(layers, y0):
        def body(y, lp):
            return _ees_step(block_fn, lp, y, step), None

        y, _ = jax.lax.scan(body, y0, layers)
        return y

    @jax.custom_vjp
    def run(layers, y0):
        return _forward(layers, y0)

    def fwd(layers, y0):
        y = _forward(layers, y0)
        return y, (layers, y)

    def bwd(res, ct_y):
        layers, y_final = res

        rev_layers = jax.tree_util.tree_map(lambda a: jnp.flip(a, axis=0), layers)

        def body(carry, lp):
            y, ct = carry
            # reconstruct the layer input (near-reversibility of EES)
            y_prev = _ees_step(block_fn, lp, y, -step)
            # exact cotangents through the re-played step
            _, vjp = jax.vjp(lambda p, yy: _ees_step(block_fn, p, yy, step), lp, y_prev)
            ct_lp, ct_prev = vjp(ct)
            return (y_prev, ct_prev), ct_lp

        (_, ct_y0), ct_layers_rev = jax.lax.scan(body, (y_final, ct_y), rev_layers)
        ct_layers = jax.tree_util.tree_map(lambda a: jnp.flip(a, axis=0), ct_layers_rev)
        return ct_layers, ct_y0

    run.defvjp(fwd, bwd)
    return run(layers, y0)
