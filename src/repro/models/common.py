"""Shared model plumbing: options, sharding policy, dtype helpers."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["ModelOptions", "ShardingPolicy", "dtype_of", "constrain"]


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Activation sharding constraints (None = leave to the compiler).

    ``batch_axes`` shards the batch dim of activations; ``model_axis`` shards
    heads / ffn-hidden / experts; ``seq_axes`` (optional) shards the sequence
    dim instead of batch for long-context small-batch cells (SP).
    """

    batch_axes: Optional[tuple] = None  # e.g. ("pod", "data")
    model_axis: Optional[str] = None  # e.g. "model"
    seq_axes: Optional[tuple] = None  # e.g. ("data",) for long-context

    def hidden(self, h):
        """(B, S, D) activation constraint."""
        if self.batch_axes is None and self.seq_axes is None:
            return h
        return jax.lax.with_sharding_constraint(
            h, P(self.batch_axes, self.seq_axes, None)
        )

    def ffn(self, h):
        """(B, S, F) hidden constraint: model-shard the wide dim."""
        if self.batch_axes is None and self.model_axis is None:
            return h
        return jax.lax.with_sharding_constraint(
            h, P(self.batch_axes, self.seq_axes, self.model_axis)
        )

    def heads(self, x):
        """(B, H, S, hd) attention layout constraint."""
        if self.batch_axes is None and self.model_axis is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, P(self.batch_axes, self.model_axis, self.seq_axes, None)
        )

    def moe_dispatch(self, x):
        """(groups, E, cap, d) expert-parallel layout: groups on the batch
        axes, experts on the model axis."""
        if self.batch_axes is None and self.model_axis is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, P(self.batch_axes, self.model_axis, None, None)
        )


NO_SHARDING = ShardingPolicy()


@dataclasses.dataclass(frozen=True)
class ModelOptions:
    remat: bool = False  # rematerialise each layer (training memory lever)
    use_flash: str = "auto"  # attention kernel dispatch: auto | never | interpret
    attn_chunk: int = 512  # q-chunk for the non-flash memory-bounded path
    shard: ShardingPolicy = NO_SHARDING
    logits_f32: bool = True  # CE loss in f32 (cast at the head)
    ssd_chunk: int = 128
    # Decode-cache layout pins: name -> PartitionSpec for the *per-layer*
    # cache leaves inside the decode scan (leading layer axis stripped).
    # Without these, SPMD can choose to all-gather the KV cache to satisfy a
    # head-sharded q — catastrophic at 32k context (see EXPERIMENTS.md §Perf).
    cache_constraints: Optional[dict] = None
    # "real" computes attention/SSD mixing; "stub" replaces the sequence-mixing
    # inner op with an identity of the right shape — used ONLY by the dry-run
    # cost methodology to isolate kernel-eliminable HBM traffic (never for
    # actual compute).
    attn_impl: str = "real"
    # Pin the residual stream to bf16 at layer boundaries with an
    # optimization barrier: prevents SPMD from hoisting the f32 norm upcast
    # above the TP all-reduce (which would double all-reduce bytes).
    bf16_ar_barrier: bool = False

    def constrain_cache(self, name: str, x):
        if self.cache_constraints is None or name not in self.cache_constraints:
            return x
        return jax.lax.with_sharding_constraint(x, self.cache_constraints[name])


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[
        name
    ]


def constrain(x, spec: Optional[P]):
    return x if spec is None else jax.lax.with_sharding_constraint(x, spec)
