"""LM architecture substrate (config-driven, pure-JAX pytree models)."""
from .common import ModelOptions, ShardingPolicy
from .transformer import (
    forward,
    init_cache,
    init_params,
    loss_fn,
    make_serve_step,
    make_train_step,
    serve_step,
)

__all__ = [
    "ModelOptions",
    "ShardingPolicy",
    "forward",
    "init_cache",
    "init_params",
    "loss_fn",
    "make_serve_step",
    "make_train_step",
    "serve_step",
]
