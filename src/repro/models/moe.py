"""Mixture-of-Experts layer: top-k routing with fixed expert capacity.

Dispatch is sort-based rather than GShard one-hot-einsum based: token->expert
assignments are grouped by expert with an argsort, each expert takes its first
``capacity`` tokens (overflow dropped, standard for capacity-based MoE), runs
a dense SwiGLU on an (E, C, d) batch — one MXU-friendly batched matmul — and
results scatter back weighted by the router gate.

Sharding: expert tensors are sharded over the "model" axis (EP).  Under SPMD
the (E, C, d) regrouped activations reshard from data-parallel tokens to
expert-parallel slots, which lowers to the expected all-to-all pair around the
expert compute (inspected in the dry-run; see EXPERIMENTS.md §Roofline).

Aux losses: load-balancing (Switch-style) + router z-loss, returned to the
caller for the training objective.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from .common import ModelOptions
from .layers import apply_norm

__all__ = ["init_moe", "moe_block"]


def init_moe(cfg, key, dtype):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    sd_in, sd_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    return {
        "ln": jnp.ones((d,), dtype) if cfg.norm == "rmsnorm" else None,
        "router": (jax.random.normal(ks[0], (d, e)) * sd_in).astype(jnp.float32),
        "wg": (jax.random.normal(ks[1], (e, d, f)) * sd_in).astype(dtype),
        "wu": (jax.random.normal(ks[2], (e, d, f)) * sd_in).astype(dtype),
        "wd": (jax.random.normal(ks[3], (e, f, d)) * sd_out).astype(dtype),
    }


def _capacity(cfg, n_tokens: int) -> int:
    cap = int(
        math.ceil(n_tokens * cfg.moe_top_k * cfg.capacity_factor / cfg.n_experts)
    )
    return max(cap, cfg.moe_top_k)


def _dispatch_group(flat, gate_vals, gate_idx, e: int, k: int, cap: int):
    """Token->slot routing for one group: returns (xe (e, cap, d), scatter info)."""
    n, d = flat.shape
    flat_expert = gate_idx.reshape(-1)  # (n*k,)
    flat_gate = gate_vals.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(n), k)

    order = jnp.argsort(flat_expert)  # group assignments by expert
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]
    # position within the expert's group
    pos_in_expert = jnp.arange(n * k) - jnp.searchsorted(
        sorted_expert, sorted_expert, side="left"
    )
    keep = pos_in_expert < cap
    slot = jnp.where(keep, sorted_expert * cap + pos_in_expert, e * cap)  # drop -> OOB
    xe = jnp.zeros((e * cap + 1, d), flat.dtype).at[slot].set(flat[sorted_token])
    return xe[:-1].reshape(e, cap, d), (keep, slot, sorted_token, sorted_gate)


def _combine_group(down, info, n: int, e: int, cap: int):
    keep, slot, sorted_token, sorted_gate = info
    d = down.shape[-1]
    flat_out = down.reshape(e * cap, d)
    contrib = jnp.where(keep[:, None], flat_out[jnp.clip(slot, 0, e * cap - 1)], 0.0)
    return jnp.zeros((n, d), down.dtype).at[sorted_token].add(
        contrib * sorted_gate[:, None].astype(down.dtype)
    )


def moe_block(cfg, p, x, opts: ModelOptions) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux_loss scalar).

    Routing is *grouped per sequence* (GShard-style groups): each batch row
    sorts/dispatches its own S*k assignments with capacity per sequence, so
    under SPMD the sort is local to the data shard and only the (groups,
    experts, capacity, d) dispatch crosses the mesh (the EP all-to-all).
    Degenerate groups (S*k < experts, e.g. decode) use one global group.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k

    h = apply_norm(cfg.norm, p["ln"], x)
    logits = h.astype(jnp.float32) @ p["router"]  # (b, s, e)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (b, s, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- aux losses (global) ----------------------------------------------
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(gate_idx, e), axis=2), axis=(0, 1))
    lb_loss = e * jnp.sum(me * ce) / k
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = (lb_loss + 1e-3 * z_loss).astype(jnp.float32)

    grouped = s * k >= e  # per-sequence groups when each row fills experts
    if grouped:
        n = s
        cap = _capacity(cfg, n)
        xe, info = jax.vmap(
            lambda f, gv, gi: _dispatch_group(f, gv, gi, e, k, cap)
        )(h, gate_vals, gate_idx)  # xe: (b, e, cap, d)
        # Pin the EP layout: groups stay data-sharded, experts model-sharded.
        # Without this SPMD may replicate the dispatch buffers (measured 3-15x
        # collective blow-up; EXPERIMENTS.md §Perf iteration 6).
        xe = opts.shard.moe_dispatch(xe)
        gate_h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["wg"]))
        up_h = jnp.einsum("gecd,edf->gecf", xe, p["wu"])
        down = jnp.einsum("gecf,efd->gecd", gate_h * up_h, p["wd"])
        down = opts.shard.moe_dispatch(down)
        out = jax.vmap(lambda dn, inf: _combine_group(dn, inf, n, e, cap))(down, info)
        out = out.reshape(b, s, d)
    else:
        n = b * s
        cap = _capacity(cfg, n)
        xe, info = _dispatch_group(
            h.reshape(n, d), gate_vals.reshape(n, k), gate_idx.reshape(n, k), e, k, cap
        )
        gate_h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"]))
        up_h = jnp.einsum("ecd,edf->ecf", xe, p["wu"])
        down = jnp.einsum("ecf,efd->ecd", gate_h * up_h, p["wd"])
        out = _combine_group(down, info, n, e, cap).reshape(b, s, d)
    return out.astype(x.dtype), aux
